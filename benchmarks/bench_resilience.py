"""Resilience-layer benchmarks: the cost of surviving faults.

Five scenarios over the same simulated-MR / streaming instances, emitted as
``BENCH_resilience.json`` and gated by ``benchmarks/compare.py``:

* ``mr-nofault``       — policy armed, no injector (the reference leg: what
  the per-reducer resilient dispatch costs vs nothing going wrong);
* ``mr-retry``         — one reducer killed once and replayed;
* ``mr-degrade``       — one reducer lost for good (survivor merge);
* ``stream-checkpoint``— streaming with periodic SMM checkpoints;
* ``stream-resume``    — the same stream killed mid-pass and resumed.

Each row carries the resilience counters (``retries``,
``failures_injected``, ``checkpoints_written``, ``reducers_recovered``)
from a separate traced pass; the counter gate treats them as *exact*
budgets — a no-fault run that starts retrying, or a checkpoint cadence
that silently changes, fails the gate even when wall-clock hides it.
"""
from __future__ import annotations

import tempfile
import time
from typing import Dict, List

import numpy as np

import repro
from repro.distributed import FailureInjector, ResiliencePolicy

#: resilience counters carried per row (exact, deterministic)
RESILIENCE_COUNTERS = ("retries", "failures_injected", "checkpoints_written",
                       "reducers_recovered")


def _counters_of(fn) -> Dict[str, int]:
    from repro.obs.trace import RunTrace, activate

    tr = RunTrace(enabled=True)
    with activate(tr):
        fn()
    return {k: int(tr.counters[k]) for k in RESILIENCE_COUNTERS}


def run(quick: bool = True) -> List[Dict]:
    n = 2 ** 14 if quick else 2 ** 18
    k, kprime, reducers = 8, 32, 8
    pts = np.random.default_rng(11).normal(size=(n, 8)).astype(np.float32)
    chunks = [pts[i::16] for i in range(16)]

    def mr(pol):
        def go():
            return repro.diversify(pts, k=k, execution=repro.ExecutionSpec(
                mode="mapreduce", num_reducers=reducers, kprime=kprime, b=1,
                resilience=pol()))
        return go

    def _stream_once(pol):
        return repro.diversify(
            repro.ProblemSpec(points=iter(chunks), k=k, dim=8),
            repro.ExecutionSpec(mode="streaming", kprime=kprime,
                                resilience=pol))

    def stream_checkpoint():
        # fresh dir per call: a reused dir would resume instead of stream
        with tempfile.TemporaryDirectory() as d:
            return _stream_once(ResiliencePolicy(checkpoint_dir=d,
                                                 checkpoint_every=3))

    def stream_resume():
        with tempfile.TemporaryDirectory() as d:
            try:
                _stream_once(ResiliencePolicy(
                    on_failure="raise", checkpoint_dir=d, checkpoint_every=3,
                    injector=FailureInjector(fail_at=("chunk:11",))))
            except RuntimeError:
                pass                       # killed at chunk 11 as scripted
            return _stream_once(ResiliencePolicy(checkpoint_dir=d,
                                                 checkpoint_every=3))

    scenarios = [
        ("mr-nofault", mr(lambda: ResiliencePolicy(max_retries=2))),
        ("mr-retry", mr(lambda: ResiliencePolicy(
            max_retries=2,
            injector=FailureInjector(fail_at=("reducer:3",))))),
        ("mr-degrade", mr(lambda: ResiliencePolicy(
            on_failure="degrade",
            injector=FailureInjector(fail_at=("reducer:3",))))),
        ("stream-checkpoint", stream_checkpoint),
        ("stream-resume", stream_resume),
    ]
    rows = []
    for name, fn in scenarios:
        fn()  # warm up jit caches
        t0 = time.perf_counter()
        res = fn()
        dt = time.perf_counter() - t0
        rows.append({
            "path": name, "n": n, "k": k, "k'": kprime,
            "reducers": reducers,
            "time_s": round(dt, 4),
            "value": round(float(res.value), 4),
            "degraded": bool(getattr(res.cert, "degraded", False)),
            "counters": _counters_of(fn),
        })
        print(f"[resilience] {name}: {dt:.3f}s "
              f"counters={rows[-1]['counters']}")
    return rows


def emit_json(rows: List[Dict], path: str = "BENCH_resilience.json") -> None:
    import json
    import platform

    import jax

    doc = {
        "benchmark": "resilience",
        "device": jax.devices()[0].device_kind,
        "backend": jax.default_backend(),
        "python": platform.python_version(),
        "rows": rows,
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"[resilience] wrote {path} ({len(rows)} rows)")


if __name__ == "__main__":
    emit_json(run(quick=True))
