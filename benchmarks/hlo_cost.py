"""Loop-aware cost model over compiled HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
scan-over-layers transformer therefore reports 1-layer FLOPs (verified in
EXPERIMENTS.md §Dry-run).  This analyzer re-walks the optimized HLO text,
builds the call graph (fusion ``calls=``, while ``body=/condition=``,
``to_apply=``), extracts while trip counts from the loop-condition's
compare-against-constant, and weights every computation by the product of
enclosing trip counts.

Accounting conventions (documented for the roofline):
* FLOPs: 2·|result|·K for every ``dot``; elementwise/reduce ops are counted
  at 1 flop per output element (they are noise next to the dots).
* bytes: operands + result at each instruction *call site*; fusion-internal
  instructions contribute FLOPs but not bytes (fusions read inputs once) —
  matching XLA's own fusion-aware traffic model.
* collectives: operand bytes of all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute, weighted by trip counts.  (all-gather and
  reduce-scatter report their *large* (gathered/pre-scatter) shape; wire
  bytes per device are ~(n-1)/n of that and we leave the ratio at 1 for a
  conservative collective term.)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "s32": 4, "u64": 8, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "s4": 1, "u4": 1}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)|"
                       r"body=%?([\w\.\-]+),\s*condition=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

_SKIP_BYTES_OPS = ("parameter", "constant", "get-tuple-element", "tuple",
                   "bitcast", "after-all", "iota")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    rhs: str
    op: str
    result_type: str


@dataclasses.dataclass
class CostReport:
    flops: float = 0.0
    bytes: float = 0.0
    collective: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    bytes_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    flops_by_op: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    @property
    def collective_total(self) -> float:
        return float(sum(self.collective.values()))

    def top_bytes(self, n=12):
        return sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:n]


_OPNAME_RE = re.compile(
    r"^(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?(?:\s*,\s*"
    r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)*)\s+([\w\-]+)\(")


def _parse(hlo: str):
    """-> (computations: name -> [Instr], whiles, entry_name, shapes)."""
    comps: Dict[str, List[Instr]] = {}
    shapes: Dict[str, str] = {}
    cur: Optional[str] = None
    entry = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//"):
            continue
        if s.startswith("HloModule"):
            continue
        mc = _COMP_RE.match(s)
        if mc and s.endswith("{"):
            cur = mc.group(1)
            comps[cur] = []
            if line.startswith("ENTRY") or raw.startswith("ENTRY"):
                entry = cur
            continue
        if s.startswith("ENTRY"):
            mc2 = re.match(r"ENTRY\s+%?([\w\.\-]+)", s)
            if mc2:
                cur = mc2.group(1)
                comps[cur] = []
                entry = cur
            continue
        if s == "}":
            continue
        md = _DEF_RE.match(s)
        if md and cur is not None:
            name, rhs = md.group(1), md.group(2)
            # result type = prefix of rhs up to the op name
            mo = _OPNAME_RE.match(rhs)
            op = mo.group(1) if mo else ""
            rtype = rhs.split(op + "(")[0] if op else rhs
            comps[cur].append(Instr(name=name, rhs=rhs, op=op,
                                    result_type=rtype))
            shapes[name] = rtype
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry, shapes


def _trip_count(cond_comp: List[Instr]) -> int:
    """Trip count heuristic: the constant compared against in the condition.

    jax scans/fori_loops lower to `compare(counter, constant(N), LT)`; the
    counter starts at 0 (scan) or the fori lower bound, so N is an upper
    bound on trips — exact for scan, off by `lower` for fori(lower>0)."""
    consts = []
    for ins in cond_comp:
        m = re.search(r"constant\((\d+)\)", ins.rhs)
        if m and ins.result_type.strip().startswith(("s32", "s64", "u32",
                                                     "u64")):
            consts.append(int(m.group(1)))
    return max(consts) if consts else 1


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    dims = _shape_dims(ins.result_type)
    out = 1.0
    for d in dims:
        out *= d
    # operand may be printed bare (`dot(%lhs,`) or typed
    # (`dot(f32[128,256]{1,0} %lhs,`) depending on the XLA version
    m = re.search(r"dot\([^%)]*%([\w\.\-]+)", ins.rhs)
    k = 1.0
    mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
    if m and mc and m.group(1) in shapes:
        lhs_dims = _shape_dims(shapes[m.group(1)])
        for ci in mc.group(1).split(","):
            if ci and int(ci) < len(lhs_dims):
                k *= lhs_dims[int(ci)]
    return 2.0 * out * k


def _param_charges(callee: List[Instr]) -> Dict[int, float]:
    """For a fusion computation, decide per-parameter byte charges.

    A parameter consumed ONLY by gather/dynamic-slice ops is charged at the
    sum of those ops' result sizes (the rows actually touched), not the full
    array — otherwise embedding lookups / per-round center gathers would be
    charged at full-table size per call."""
    params: Dict[str, int] = {}
    for ins in callee:
        m = re.search(r"parameter\((\d+)\)", ins.rhs)
        if m and ins.op == "parameter":
            params[ins.name] = int(m.group(1))
    charges: Dict[int, float] = {}
    for pname, pidx in params.items():
        gathered = 0.0
        only_gather = True
        for ins in callee:
            if ins.op == "parameter":
                continue
            ops = _OPERAND_RE.findall(
                ins.rhs.split("(", 1)[1] if "(" in ins.rhs else "")
            if pname not in ops:
                continue
            if ins.op in ("gather", "dynamic-slice"):
                gathered += _shape_bytes(ins.result_type)
            else:
                only_gather = False
                break
        if only_gather and gathered > 0:
            charges[pidx] = gathered
    return charges


def analyze_hlo(hlo: str) -> CostReport:
    comps, entry, shapes = _parse(hlo)
    # call graph weights
    weights: Dict[str, float] = defaultdict(float)
    fusion_called: set = set()
    while_meta: Dict[str, Tuple[str, str]] = {}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.op == "while" or " while(" in " " + ins.rhs:
                mw = _WHILE_RE.search(ins.rhs)
                if mw:
                    g = mw.groups()
                    cond, body = (g[0], g[1]) if g[0] else (g[3], g[2])
                    while_meta[cname + "/" + ins.name] = (cond, body)
            for callee in _CALLS_RE.findall(ins.rhs):
                fusion_called.add(callee)

    def visit(cname: str, w: float, seen: Tuple[str, ...] = ()):
        if cname not in comps or cname in seen:
            return
        weights[cname] += w
        for ins in comps[cname]:
            mw = _WHILE_RE.search(ins.rhs) if ("while(" in ins.rhs) else None
            if mw:
                g = mw.groups()
                cond, body = (g[0], g[1]) if g[0] else (g[3], g[2])
                trips = _trip_count(comps.get(cond, []))
                visit(cond, w * trips, seen + (cname,))
                visit(body, w * trips, seen + (cname,))
            else:
                for callee in _CALLS_RE.findall(ins.rhs):
                    visit(callee, w, seen + (cname,))

    visit(entry, 1.0)

    report = CostReport()
    for cname, instrs in comps.items():
        w = weights.get(cname, 0.0)
        if w == 0.0:
            continue
        in_fusion = cname in fusion_called and not cname.startswith("region")
        for ins in instrs:
            if ins.op == "dot":
                fl = w * _dot_flops(ins, shapes)
                report.flops += fl
                report.flops_by_op["dot"] += fl
            elif ins.op in ("add", "multiply", "subtract", "divide", "tanh",
                            "exponential", "rsqrt", "maximum", "minimum",
                            "reduce", "convert", "select", "compare"):
                dims = _shape_dims(ins.result_type)
                n = 1.0
                for d in dims:
                    n *= d
                report.flops += w * n
                report.flops_by_op[ins.op] += w * n
            # bytes: call-site accounting only
            if in_fusion:
                continue
            if ins.op in _SKIP_BYTES_OPS or not ins.op:
                continue
            if "while(" in ins.rhs:
                continue  # carried tuple isn't real traffic per trip
            nbytes = _shape_bytes(ins.result_type)
            charges: Dict[int, float] = {}
            if ins.op == "fusion":
                mcall = _CALLS_RE.search(ins.rhs)
                if mcall and mcall.group(1) in comps:
                    charges = _param_charges(comps[mcall.group(1)])
            arglist = (ins.rhs.split("(", 1)[1].split(")", 1)[0]
                       if "(" in ins.rhs else "")
            operands = _OPERAND_RE.findall(arglist)
            for oi, operand in enumerate(operands):
                if operand in shapes:
                    full = _shape_bytes(shapes[operand])
                    nbytes += min(charges.get(oi, full), full)
            report.bytes += w * nbytes
            report.bytes_by_op[ins.op] += w * nbytes
            for cop in COLLECTIVE_OPS:
                if ins.op.startswith(cop) and not ins.op.endswith("-done"):
                    report.collective[cop] += w * _shape_bytes(ins.result_type)
                    break
    return report
