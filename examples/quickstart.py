"""Quickstart: diversity maximization over a point set, all six measures.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro
from repro.core import MEASURES, SEQ_ALPHA
from repro.data import sphere_dataset

def main():
    # the paper's hardest synthetic: 8 planted far points + bulk in a ball
    pts = sphere_dataset(n=20_000, k=8, dim=3, seed=0)
    print(f"dataset: {pts.shape[0]} points in R^{pts.shape[1]}")
    for measure in MEASURES:
        res = repro.diversify(pts, k=8, measure=measure,
                              execution=repro.ExecutionSpec(kprime=64))
        print(f"{measure:20s} value={res.value:8.4f}  "
              f"(coreset {res.coreset.size} pts, "
              f"sequential alpha={SEQ_ALPHA[measure]})")
    # the planted points live on the unit sphere: check we found them
    sol = repro.diversify(pts, k=8, measure="remote-edge",
                          execution=repro.ExecutionSpec(kprime=64)).solution
    print("\nremote-edge solution radii (planted points have r=1):")
    print(np.round(np.linalg.norm(sol, axis=1), 3))


if __name__ == "__main__":
    main()
