"""Streaming pipeline (paper §4/§6.1): 1-pass SMM for remote-edge and the
2-pass generalized scheme (SMM-GEN + instantiation) for remote-clique.

    PYTHONPATH=src python examples/streaming_pipeline.py
"""
import time

import numpy as np

from repro.core import StreamingCoreset, diversity, instantiate, solve
from repro.core.metrics import get_metric
from repro.data import sphere_dataset, stream


def main():
    n, k, kprime = 200_000, 16, 256
    pts = sphere_dataset(n, k=k, dim=3, seed=1)

    # --- 1-pass: SMM core-set -> sequential solver (Thm 3)
    smm = StreamingCoreset(k=k, kprime=kprime, dim=3, mode="plain")
    t0 = time.perf_counter()
    for chunk in stream(pts, 8192):
        smm.update(chunk)
    cs = smm.finalize()
    dt = time.perf_counter() - t0
    pool = cs.compact()
    idx = solve("remote-edge", pool, k)
    m = get_metric("euclidean")
    import jax.numpy as jnp
    dm = np.asarray(m.pairwise(jnp.asarray(pool[idx]), jnp.asarray(pool[idx])))
    print(f"1-pass SMM: coreset {cs.size} pts, {int(n / dt):,} pts/s, "
          f"remote-edge={diversity('remote-edge', dm):.4f}")

    # --- 2-pass: SMM-GEN generalized core-set (Thm 9)
    gen = StreamingCoreset(k=k, kprime=kprime, dim=3, mode="gen")
    for chunk in stream(pts, 8192):
        gen.update(chunk)
    g = gen.finalize()
    p, mult = g.compact()
    idx = solve("remote-clique", p, k, weights=mult)
    uniq, counts = np.unique(idx, return_counts=True)
    # second pass: instantiate distinct delegates within radius of kernels
    sol = instantiate(p[uniq], counts, pts, float(g.radius))
    dm = np.asarray(m.pairwise(jnp.asarray(sol), jnp.asarray(sol)))
    print(f"2-pass SMM-GEN: s(T)={int((np.asarray(g.multiplicity) > 0).sum())} "
          f"kernels (expanded {g.expanded_size}), "
          f"remote-clique={diversity('remote-clique', dm):.2f}")


if __name__ == "__main__":
    main()
