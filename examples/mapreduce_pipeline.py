"""MapReduce pipeline (paper §5): 2-round and 3-round generalized schemes,
parallelism sweep and the adversarial-partitioning experiment of §7.2.

    PYTHONPATH=src python examples/mapreduce_pipeline.py
"""
import time

import repro
from repro.data import sphere_dataset


def main():
    n, k = 400_000, 32
    pts = sphere_dataset(n, k=k, dim=3, seed=2)
    print(f"{n:,} points, k={k}\n")
    print("reducers  k'    partition     remote-edge   time")
    for reducers in (4, 16):
        for kprime in (64, 256):
            for part in ("random", "adversarial"):
                t0 = time.perf_counter()
                v = repro.diversify(
                    pts, k=k, measure="remote-edge",
                    execution=repro.ExecutionSpec(
                        mode="mapreduce", num_reducers=reducers,
                        kprime=kprime, partition=part)).value
                dt = time.perf_counter() - t0
                print(f"{reducers:8d}  {kprime:4d}  {part:12s}  "
                      f"{v:11.4f}   {dt:5.2f}s")
    # 3-round generalized scheme for remote-clique (Thm 10)
    t0 = time.perf_counter()
    v3 = repro.diversify(
        pts, k=k, measure="remote-clique",
        execution=repro.ExecutionSpec(mode="mapreduce", num_reducers=16,
                                      kprime=128, generalized=True)).value
    print(f"\n3-round GMM-GEN remote-clique: {v3:.2f} "
          f"({time.perf_counter() - t0:.2f}s)")


if __name__ == "__main__":
    main()
