"""End-to-end serving driver (the paper's motivating application):
serve a small LM with batched requests, generate candidate continuations,
then present the k most DIVERSE results via the paper's remote-edge
machinery over embedding space.

    PYTHONPATH=src python examples/serve_diverse.py [--arch internlm2-1.8b]
"""
import argparse

import numpy as np
import jax

import repro.models as M
from repro.configs import get_config
from repro.data import embed_examples
from repro.models.common import ShardingRules
import repro
from repro.serving import Request, ServingEngine

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None, act_heads=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--num-candidates", type=int, default=16)
    ap.add_argument("--k", type=int, default=4)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)   # CPU-sized backbone
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, RULES, params, batch=4, capacity=64)

    # batched requests: the same query sampled with different prompt seeds
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(1, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new_tokens=12)
            for _ in range(args.num_candidates)]
    done = engine.generate(reqs)
    outs = np.stack([r.out for r in done])      # (n_candidates, 12)
    print(f"served {len(done)} candidates of 12 tokens each")

    # embed candidates (token histogram sketch) and pick the k most diverse
    emb = embed_examples(outs, dim=16)
    top = repro.diversify(emb, k=args.k, measure="remote-edge").indices
    print(f"\n{args.k} most diverse results (indices {top.tolist()}):")
    for i in top:
        print(f"  candidate {i:2d}: {outs[i].tolist()}")


if __name__ == "__main__":
    main()
