"""The one front door: spec -> plan -> result across every execution mode.

    PYTHONPATH=src python examples/unified_api.py
"""
import numpy as np

import repro


def main():
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(8192, 8)).astype(np.float32)
    lab = rng.integers(0, 4, size=8192)

    # --- inspect before running: the planner explains itself -------------
    p = repro.plan(repro.ProblemSpec(points=pts, k=8),
                   repro.ExecutionSpec(num_reducers=8, kprime=64, b=4))
    print(p.explain())
    res = p.execute()
    print(f"\nmapreduce: value={res.value:.3f}  "
          f"indices={sorted(res.indices.tolist())[:4]}...\n")

    # --- same problem, other modes: one spec field away ------------------
    batch = repro.diversify(pts, k=8)                     # auto -> batch
    stream = repro.diversify(
        repro.ProblemSpec(points=(pts[i:i + 1024]
                                  for i in range(0, len(pts), 1024)),
                          k=8, dim=8))                    # auto -> streaming
    print(f"batch:     value={batch.value:.3f}  "
          f"cert ratio={batch.cert.ratio:.3f}")
    print(f"streaming: value={stream.value:.3f}  "
          f"cert kind={stream.cert.kind}")

    # --- constrained: labels in the ProblemSpec, planner does the rest ---
    fair = repro.diversify(pts, k=8, labels=lab, quotas=[2, 2, 2, 2])
    print(f"fair:      value={fair.value:.3f}  "
          f"per-group={np.bincount(lab[fair.indices], minlength=4).tolist()}")

    # --- telemetry: every path reports its phases -------------------------
    phases = ", ".join(f"{ph['name']}={ph['seconds'] * 1e3:.1f}ms"
                       for ph in fair.telemetry["phases"])
    print(f"phases:    {phases}")


if __name__ == "__main__":
    main()
