"""Fair data curation: a balanced, diverse training subset under matroid
constraints (the constrained-diversity subsystem end to end).

A synthetic pool mixes examples from several "domains" (code, chat, web, …)
in skewed proportions.  Plain diversity selection follows the embedding
geometry and can starve small domains; ``repro.diversify(...)`` with
``labels=``/``quotas=`` constrains the pick so every domain lands its quota —
maximally diverse *within* that fairness constraint (per-group core-sets +
feasible-greedy/local-search, see ``repro.constrained``).  Beyond exact
quotas, the matroid oracle layer expresses SLO bands (``PartitionMatroid``
with ``q_min``/``q_max``) and slot-eligibility rules
(``TransversalMatroid``) with the same machinery.

    PYTHONPATH=src python examples/fair_selection.py --keep 24
"""
import argparse

import numpy as np

import repro
from repro.constrained import PartitionMatroid, TransversalMatroid
from repro.data import balanced_quotas, embed_examples


def _select(emb, keep, *, num_reducers=1, **problem_kw):
    """Diverse-pick row indices through the facade."""
    res = repro.diversify(
        repro.ProblemSpec(points=emb, k=keep, measure="remote-edge",
                          **problem_kw),
        repro.ExecutionSpec(
            mode="mapreduce" if num_reducers > 1 else "batch",
            num_reducers=num_reducers if num_reducers > 1 else None,
            kprime=64))
    return res.indices

DOMAINS = ["code", "chat", "web", "papers"]
MIX = [0.55, 0.25, 0.15, 0.05]          # skewed pool: papers is tiny


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pool", type=int, default=2000)
    ap.add_argument("--keep", type=int, default=24)
    ap.add_argument("--seq", type=int, default=48)
    ap.add_argument("--reducers", type=int, default=1)
    args = ap.parse_args()

    # synthetic labelled pool: each domain samples tokens from its own range,
    # so domains are separated in embedding space and sized per MIX
    rng = np.random.default_rng(0)
    labels = rng.choice(len(DOMAINS), size=args.pool, p=MIX)
    pool = np.zeros((args.pool, args.seq), np.int64)
    for g in range(len(DOMAINS)):
        rows = labels == g
        pool[rows] = rng.integers(1000 * g, 1000 * g + 600,
                                  size=(rows.sum(), args.seq))
    emb = embed_examples(pool, dim=16)

    counts = np.bincount(labels, minlength=len(DOMAINS))
    print("pool composition:")
    for name, c in zip(DOMAINS, counts):
        print(f"  {name:8s} {c:5d}  ({c / args.pool:5.1%})")

    # unconstrained pick: whatever the geometry favors
    plain = _select(emb, args.keep)
    plain_counts = np.bincount(labels[plain], minlength=len(DOMAINS))

    # fair pick: balanced quotas across domains (capped by domain size)
    quotas = balanced_quotas(labels, args.keep)
    fair = _select(emb, args.keep, labels=labels, quotas=quotas,
                   num_reducers=args.reducers)
    fair_counts = np.bincount(labels[fair], minlength=len(DOMAINS))

    print(f"\nselected {args.keep} examples:")
    print(f"  {'domain':8s} {'plain':>6s} {'fair':>6s} {'quota':>6s}")
    for g, name in enumerate(DOMAINS):
        print(f"  {name:8s} {plain_counts[g]:6d} {fair_counts[g]:6d} "
              f"{quotas[g]:6d}")
    assert np.array_equal(fair_counts, quotas), "quotas must be exact"

    # SLO-band pick: exact quotas are often too rigid in production — an
    # operator promises "at least 2 papers, no domain above half the slate".
    # Quota RANGES express that directly via the matroid oracle layer.
    band = PartitionMatroid(
        q_min=[0, 0, 0, min(2, int(counts[3]))],
        q_max=[args.keep // 2] * len(DOMAINS), k=args.keep)
    banded = _select(emb, args.keep, labels=labels, matroid=band)
    banded_counts = np.bincount(labels[banded], minlength=len(DOMAINS))
    assert band.basis_feasible(banded_counts)

    # slot-constrained pick: the slate has args.keep "roles"; the first
    # quarter of the roles only accept code/chat (a transversal matroid)
    elig = np.ones((len(DOMAINS), args.keep), bool)
    elig[2:, : args.keep // 4] = False       # web/papers barred from 1st 1/4
    trans = TransversalMatroid(elig)
    slotted = _select(emb, args.keep, labels=labels, matroid=trans)
    assert trans.independence_oracle(labels[slotted])

    print(f"\nselected {args.keep} examples (banded = q_min/q_max SLO, "
          f"slotted = transversal roles):")
    print(f"  {'domain':8s} {'banded':>7s} {'slotted':>8s}")
    slotted_counts = np.bincount(labels[slotted], minlength=len(DOMAINS))
    for g, name in enumerate(DOMAINS):
        print(f"  {name:8s} {banded_counts[g]:7d} {slotted_counts[g]:8d}")

    print("\nfair selection honored every per-domain quota; the curated "
          "subset is ready for the training loop "
          "(see examples/train_diverse_data.py).")


if __name__ == "__main__":
    main()
