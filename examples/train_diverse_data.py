"""End-to-end training driver: diversity-curated data + fault-tolerant loop.

1. Build a pool of synthetic examples, embed them, select the most diverse
   subset with the paper's MR core-set (data curation).
2. Train an LM on the curated stream for a few hundred steps under the
   TrainingSupervisor (async checkpoints + injected-failure resume).

Default runs a CPU-sized reduced config; --arch/--steps scale it up on real
hardware (the same code path the launcher uses on a pod).

    PYTHONPATH=src python examples/train_diverse_data.py --steps 300
"""
import argparse
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

import repro.models as M
from repro.checkpoint import CheckpointManager
from repro.configs import get_config
import repro
from repro.data import embed_examples, lm_batch
from repro.distributed import (FailureInjector, ResiliencePolicy,
                               TrainingSupervisor)
from repro.models.common import ShardingRules
from repro.train import AdamW, cosine_schedule, make_train_step

RULES = ShardingRules(batch=(), heads=None, kv_heads=None, d_ff=None,
                      vocab=None, experts=None, fsdp=None, head_dim=None,
                      state=None, act_heads=None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--pool", type=int, default=512)
    ap.add_argument("--keep", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"arch={cfg.arch}  params={M.count_params(cfg):,}")

    # --- 1. diversity-driven data curation (the paper's technique)
    rng = np.random.default_rng(0)
    pool = rng.integers(0, cfg.vocab_size, size=(args.pool, args.seq + 1))
    emb = embed_examples(pool[:, :-1], dim=16)
    keep_idx = repro.diversify(
        emb, k=args.keep, measure="remote-edge",
        execution=repro.ExecutionSpec(mode="mapreduce", num_reducers=4,
                                      kprime=64)).indices
    curated = pool[keep_idx]
    print(f"curated {len(keep_idx)}/{args.pool} examples by remote-edge "
          f"diversity")

    def batch_fn(step):
        r = np.random.default_rng(step)
        rows = r.integers(0, curated.shape[0], size=args.batch)
        sel = curated[rows]
        return {"tokens": jnp.asarray(sel[:, :-1], jnp.int32),
                "labels": jnp.asarray(sel[:, 1:], jnp.int32)}

    # --- 2. fault-tolerant training
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(weight_decay=0.01)
    state = (params, opt.init(params))
    lr = cosine_schedule(3e-3, warmup=20, total=args.steps)
    raw = jax.jit(make_train_step(cfg, RULES, opt, lr))

    def step_fn(state, batch, step):
        p, o, m = raw(state[0], state[1], batch, step)
        return (p, o), m

    with tempfile.TemporaryDirectory() as d:
        sup = TrainingSupervisor(
            CheckpointManager(d, keep_k=2),
            policy=ResiliencePolicy(
                max_retries=8, deadline_factor=3.0, checkpoint_every=50,
                injector=FailureInjector(fail_at=(args.steps // 2,))))
        sup.run(state, step_fn, args.steps, batch_fn)
        losses = sup.report.losses
        print(f"steps={sup.report.final_step}  resumes={sup.report.resumes} "
              f"(one injected failure survived)")
        print(f"loss: first10={np.mean(losses[:10]):.3f}  "
              f"last10={np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
